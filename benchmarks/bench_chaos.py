"""Degraded-mode serving under seeded chaos: elastic budget shedding,
dead-shard tolerance, and the fault-injection harness — the bad-day twin
of the ``traffic`` suite.

The paper's 1,200 QPS / 60 ms p99 (§3.3) is a fair-weather number.  This
suite stresses the resilience layer (``serving/resilience.py``) end to
end and pins its one load-bearing property: DEGRADATION IS DETERMINISTIC
AND ACCOUNTED, never silent.  Three legs feed one verdict,
``degraded_serving_agrees``:

  * **shed parity** — a chaos run (seeded latency spikes + traffic
    bursts, ``sample_fault_schedule``) against an elastic
    ``ResilienceConfig``: queue waits grow through the spike windows,
    per-request step budgets shrink (Eq. 2 is elastic — fewer steps is a
    valid coarser Monte Carlo estimate), and the recorded
    ``report.budgets`` replayed through an UNLOADED single-bucket oracle
    via ``submit(budget=...)`` must reproduce every score and id
    bit-for-bit — across backend x gather (xla/scalar, pallas/scalar,
    pallas/dma).  Shedding is a pure function of the virtual clock, and
    budgets are data on the ``(batch,)`` axis, so nothing retraces.
    Same seed twice must replay budgets AND results bit-identically.

  * **zero-fault parity** — an empty ``FaultSchedule`` plus resilience
    thresholds that never engage must be bit-identical to a plain PR 7
    open-loop run with no resilience layer at all: the bad-day machinery
    costs nothing on a good day.

  * **dead-shard tolerance** (8 forced host devices, 4-shard pod) — an
    all-``INT32_MAX`` death schedule is bit-identical to the healthy
    ``None`` path; a shard killed mid-walk has its walkers killed and
    reborn at home (``killed`` counted, distinct from capacity drops),
    its counts zeroed out of the merge, and the quality cost quantified
    as ``overlap_at_k`` against the all-alive oracle; ``revive_shards``
    restores bit-identical healthy serving.  Same death schedule replays
    bit-identically.

On CPU hosts the pallas legs run in interpret mode and the 8 "devices"
share one machine — regress on the agreement verdict, never on CPU
timings.  Needs a multi-device jax, so ``run()`` re-executes this module
in a child process with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(the driver imports suites after jax locks its device count).

Results land in ``results/bench.json`` AND merge into
``BENCH_serving.json`` as the ``chaos`` section.
"""

from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
from typing import Dict

N_DEVICES = 8
N_SHARDS = 4
BUCKETS = ((4, 2), (2, 8))    # small / large (batch, n_slots)
ORACLE_BATCH = 4              # single-bucket replay-oracle shape
MAX_WAIT_MS = 4.0
SHED_CELLS = (("xla", "scalar"), ("pallas", "scalar"), ("pallas", "dma"))


def _child_run(seed: int) -> Dict:
    """Runs inside the 8-device child process."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import counter as counter_lib
    from repro.core import distributed as dist_lib
    from repro.core import walk as walk_lib
    from repro.graphs.synthetic import (
        SyntheticGraphConfig, generate, small_test_graph, top_degree_pins,
    )
    from repro.launch.mesh import make_mesh_compat, set_mesh_compat
    from repro.serving.resilience import ResilienceConfig, overlap_at_k
    from repro.serving.server import PixieServer
    from repro.serving.traffic import (
        ChaosConfig, FaultSchedule, OpenLoopConfig, poisson_requests,
        run_open_loop, sample_fault_schedule,
    )

    def hot_pins(g, n, s):
        rng = np.random.default_rng(s)
        degs = np.asarray(g.p2b.degrees()).astype(np.float64)
        return rng.choice(g.n_pins, size=n, replace=False,
                          p=degs / degs.sum()).astype(np.int32)

    # -- leg 1: elastic shed parity + reproducibility, backend x gather ----
    sg = generate(SyntheticGraphConfig(
        n_pins=1_000, n_boards=120, n_topics=8, n_langs=2, seed=seed,
    ))
    g = sg.graph
    base = walk_lib.WalkConfig(
        n_steps=400, n_walkers=32, chunk_steps=8, top_k=20, n_p=60, n_v=3,
    )
    candidates = hot_pins(g, 48, seed)
    workload = poisson_requests(candidates, OpenLoopConfig(
        offered_qps=300.0, n_requests=16, seed=seed, max_pins=6,
    ))
    horizon = workload[-1].t_arrival
    faults = sample_fault_schedule(ChaosConfig(
        horizon_s=horizon, seed=seed + 1, n_spikes=3, spike_duration_s=0.03,
        n_bursts=2, burst_duration_s=0.02, burst_factor=4.0,
    ))
    rcfg = ResilienceConfig(
        deadline_ms=60.0, shed_start_ms=5.0, min_budget_frac=0.25,
    )

    def chaos_run(cfg):
        srv = PixieServer(
            g, cfg, seed=seed, buckets=BUCKETS, max_wait_ms=MAX_WAIT_MS,
            resilience=rcfg,
        )
        return run_open_loop(srv, workload, max_backlog_s=None,
                             faults=faults)

    shed_cells = []
    shed_all_agree = True
    any_shed = False
    reproducible = True
    for backend, gather in SHED_CELLS:
        cfg = dataclasses.replace(base, backend=backend, gather_mode=gather)
        report = chaos_run(cfg)
        # replay oracle: UNLOADED single-bucket flush with the recorded
        # budgets — different batch composition, same per-request fold_in
        # streams, so bit-parity here proves budgets (not batching or
        # timing) are the whole degradation
        oracle = PixieServer(
            g, cfg, batch_size=ORACLE_BATCH, n_slots=8, seed=seed,
        )
        for req in workload:
            oracle.submit(list(req.pins), list(req.weights), req.user_feat,
                          req_id=req.req_id,
                          budget=report.budgets[req.req_id])
        oracle_out = {r.req_id: r for r in oracle.flush()}
        agree = len(report.results) == len(workload) == len(oracle_out)
        for req in workload:
            c = report.results.get(req.req_id)
            o = oracle_out.get(req.req_id)
            if c is None or o is None:
                agree = False
                break
            agree &= bool(np.array_equal(c.scores, o.scores))
            agree &= bool(np.array_equal(c.ids, o.ids))
            if not agree:
                break
        n_shrunk = sum(
            1 for b in report.budgets.values() if b < base.n_steps
        )
        any_shed |= n_shrunk > 0
        # reproducibility: the same seed + schedule replays bit-for-bit
        replay = chaos_run(cfg)
        rep_ok = replay.budgets == report.budgets
        for rid, c in report.results.items():
            r2 = replay.results.get(rid)
            rep_ok &= r2 is not None and bool(
                np.array_equal(c.ids, r2.ids)
                and np.array_equal(c.scores, r2.scores)
            )
            if not rep_ok:
                break
        shed_all_agree &= agree
        reproducible &= bool(rep_ok)
        shed_cells.append({
            "backend": backend, "gather_mode": gather,
            "shed_matches_budget_oracle": bool(agree),
            "replay_bit_identical": bool(rep_ok),
            "n_shrunk": n_shrunk,
            "min_budget": min(report.budgets.values()),
            "n_rejected": report.n_rejected,
        })

    # -- leg 2: zero faults + never-engaging thresholds == plain run -------
    cfg = dataclasses.replace(base, backend="xla")
    plain = PixieServer(
        g, cfg, seed=seed, buckets=BUCKETS, max_wait_ms=MAX_WAIT_MS,
    )
    plain_report = run_open_loop(plain, workload, max_backlog_s=None)
    idle = PixieServer(
        g, cfg, seed=seed, buckets=BUCKETS, max_wait_ms=MAX_WAIT_MS,
        resilience=ResilienceConfig(deadline_ms=1e6, shed_start_ms=1e5),
    )
    idle_report = run_open_loop(idle, workload, max_backlog_s=None,
                                faults=FaultSchedule())
    zero_fault_ok = (
        len(plain_report.results) == len(idle_report.results) == len(workload)
        and all(b == base.n_steps for b in idle_report.budgets.values())
    )
    for rid, p in plain_report.results.items():
        q = idle_report.results.get(rid)
        zero_fault_ok &= q is not None and bool(
            np.array_equal(p.ids, q.ids)
            and np.array_equal(p.scores, q.scores)
        )
        if not zero_fault_ok:
            break

    # -- leg 3: dead-shard tolerance on a 4-shard pod ----------------------
    tsg = small_test_graph(seed)
    tg = tsg.graph
    qs = top_degree_pins(tsg, 8)
    dcfg = walk_lib.WalkConfig(
        n_steps=2_048, n_walkers=32, chunk_steps=4, top_k=20,
        n_p=30, n_v=3, bias_beta=0.0, count_boards=True,
    )
    mesh = make_mesh_compat((N_SHARDS,), ("model",))
    shg = dist_lib.shard_graph(tg, N_SHARDS)
    batch, n_slots = 4, 4
    pins = np.full((batch, n_slots), -1, np.int32)
    weights = np.zeros((batch, n_slots), np.float32)
    for b in range(batch):
        pins[b, :2] = qs[2 * b:2 * b + 2]
        weights[b, :2] = (1.0, 0.6)
    pins_j, weights_j = jnp.asarray(pins), jnp.asarray(weights)
    keys = jax.random.split(jax.random.key(seed), batch)
    never = np.iinfo(np.int32).max
    victim = 2
    death_step = 3
    dead_sched = np.full((N_SHARDS,), never, np.int32)
    dead_sched[victim] = death_step

    with set_mesh_compat(mesh):
        def engine(dead_at):
            return dist_lib.pixie_walk_sharded_batched(
                shg, pins_j, weights_j, keys, dcfg, mesh, slack=16.0,
                shard_dead_at=(
                    None if dead_at is None else jnp.asarray(dead_at)
                ),
            )

        healthy = jax.block_until_ready(engine(None))
        all_never = jax.block_until_ready(
            engine(np.full((N_SHARDS,), never, np.int32))
        )
        faulted = jax.block_until_ready(engine(dead_sched))
        faulted2 = jax.block_until_ready(engine(dead_sched))

        def folded(res):
            return np.asarray(counter_lib.fold_sharded_counts(
                res.counts, batch, n_slots, shg.pins_per_shard
            ))

        # an all-INT32_MAX schedule is value-identical to no schedule:
        # the server compiles ONE faulty program for both weathers
        never_parity = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in (
                (healthy.counts, all_never.counts),
                (healthy.steps_taken, all_never.steps_taken),
                (healthy.n_high, all_never.n_high),
            )
        ) and int(all_never.killed) == 0
        pps = shg.pins_per_shard
        dead_zeroed = bool(
            folded(faulted)[..., victim * pps:(victim + 1) * pps].sum() == 0
        )
        survivors_counted = bool(folded(faulted).sum() > 0)
        killed = int(faulted.killed)
        death_replay_ok = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in (
                (faulted.counts, faulted2.counts),
                (faulted.steps_taken, faulted2.steps_taken),
                (faulted.n_high, faulted2.n_high),
            )
        ) and int(faulted2.killed) == killed

        # server surface: kill_shard -> degraded top-k, quantified vs the
        # healthy oracle; revive_shards -> bit-identical healthy serving
        def serve(kill):
            srv = PixieServer(shg, dcfg, batch_size=batch, n_slots=n_slots,
                              seed=seed, mesh=mesh, slack=16.0)
            if kill:
                srv.kill_shard(victim, at_superstep=death_step)
            for i in range(batch):
                srv.submit([int(p) for p in pins[i] if p >= 0],
                           [float(w) for w in weights[i] if w > 0],
                           req_id=i)
            return srv, {r.req_id: r for r in srv.flush()}

        srv_h, out_h = serve(kill=False)
        srv_d, out_d = serve(kill=True)
        overlap = overlap_at_k(
            np.stack([np.asarray(out_d[i].ids) for i in range(batch)]),
            np.stack([np.asarray(out_h[i].ids) for i in range(batch)]),
        )
        degraded_differs = any(
            not np.array_equal(out_d[i].ids, out_h[i].ids)
            for i in range(batch)
        )
        srv_d.revive_shards()
        for i in range(batch):
            srv_d.submit([int(p) for p in pins[i] if p >= 0],
                         [float(w) for w in weights[i] if w > 0],
                         req_id=i)
        revived = {r.req_id: r for r in srv_d.flush()}
        revive_ok = all(
            np.array_equal(revived[i].ids, out_h[i].ids)
            and np.array_equal(revived[i].scores, out_h[i].scores)
            for i in range(batch)
        )

    dead_shard = {
        "n_shards": N_SHARDS, "victim": victim,
        "death_superstep": death_step,
        "never_schedule_matches_healthy": bool(never_parity),
        "killed": killed,
        "killed_counted": killed > 0,
        "dead_shard_counts_zeroed": dead_zeroed,
        "survivors_counted": survivors_counted,
        "death_replay_bit_identical": bool(death_replay_ok),
        "overlap_at_k": round(float(overlap), 4),
        "revive_restores_healthy": bool(revive_ok),
        "degraded_differs_from_oracle": bool(degraded_differs),
    }
    dead_shard["ok"] = bool(
        never_parity and killed > 0 and dead_zeroed and survivors_counted
        and death_replay_ok and 0.0 <= overlap <= 1.0 and revive_ok
    )

    return {
        "host_backend": jax.default_backend(),
        "pallas_interpret": jax.default_backend() == "cpu",
        "n_devices": len(jax.devices()),
        "buckets": [list(b) for b in BUCKETS],
        "n_requests": len(workload),
        "n_faults": len(faults.events),
        "shed": {
            "cells": shed_cells,
            "all_agree": bool(shed_all_agree),
            "reproducible": bool(reproducible),
            "any_shed": bool(any_shed),
        },
        "zero_fault": {"bit_identical": bool(zero_fault_ok)},
        "dead_shard": dead_shard,
    }


def run(seed: int = 0) -> Dict:
    """Driver entry: re-exec in a child with 8 forced host devices."""
    from benchmarks.common import merge_serving_section

    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={N_DEVICES}"
    ).strip()
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo, "src"), repo, env.get("PYTHONPATH", "")]
    )
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_chaos", "--child",
         "--seed", str(seed)],
        capture_output=True, text=True, env=env, cwd=repo, timeout=3600,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"bench_chaos child failed:\n{proc.stderr[-3000:]}"
        )
    ch: Dict = json.loads(proc.stdout.strip().splitlines()[-1])
    out: Dict = {"chaos": ch}
    # verdict: (1) shed-budget chaos results bit-identical to an unloaded
    # oracle dispatched with the same shrunk budgets, across backend x
    # gather, with shedding actually engaged and the whole run seed-
    # reproducible; (2) zero-fault chaos bit-identical to the plain
    # open-loop run; (3) dead-shard serving kills-and-counts, zeroes the
    # dead shard's counts, quantifies overlap, and revives bit-clean
    out["degraded_serving_agrees"] = bool(
        ch["shed"]["all_agree"]
        and ch["shed"]["reproducible"]
        and ch["shed"]["any_shed"]
        and ch["zero_fault"]["bit_identical"]
        and ch["dead_shard"]["ok"]
    )
    out["wrote"] = merge_serving_section("chaos", {
        "degraded_serving_agrees": out["degraded_serving_agrees"],
        "pallas_interpret": ch["pallas_interpret"],
        "shed": ch["shed"],
        "zero_fault": ch["zero_fault"],
        "dead_shard": ch["dead_shard"],
    })
    return out


def _child_main() -> int:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.child:
        print(json.dumps(_child_run(args.seed)))
        return 0
    print(json.dumps(run(args.seed), indent=2))
    return 0


if __name__ == "__main__":
    sys.exit(_child_main())
