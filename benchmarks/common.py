"""Shared benchmark substrate: one synthetic graph + helpers, reused by all
paper-table benchmarks so the suite builds the graph once."""

from __future__ import annotations

import functools
import json
import os
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import walk as walk_lib
from repro.graphs.synthetic import SyntheticGraph, SyntheticGraphConfig, generate

BENCH_SERVING_PATH = os.path.join(
    os.path.dirname(os.path.dirname(__file__)), "BENCH_serving.json"
)

# sections other suites merge into BENCH_serving.json; bench_smoke (which
# rewrites the base file) preserves exactly this list, so registering a new
# merged suite means adding its section name HERE, nowhere else
MERGED_SECTIONS = (
    "widepack", "dma", "batchfuse", "sharded", "traffic", "two_stage",
    "multi_interest", "chaos",
)


def merge_serving_section(name: str, payload: Dict) -> str:
    """Merge one suite's section into BENCH_serving.json; returns the path.

    The file may not exist yet (suite run before bench_smoke) or may be
    unreadable — either way the section still lands.
    """
    data: Dict = {}
    if os.path.exists(BENCH_SERVING_PATH):
        try:
            with open(BENCH_SERVING_PATH) as f:
                data = json.load(f)
        except Exception:
            data = {}
    data[name] = payload
    with open(BENCH_SERVING_PATH, "w") as f:
        json.dump(data, f, indent=2)
    return BENCH_SERVING_PATH


@functools.lru_cache(maxsize=2)
def bench_graph(scale: str = "small") -> SyntheticGraph:
    if scale == "small":
        cfg = SyntheticGraphConfig(
            n_pins=20_000, n_boards=2_000, n_topics=16, n_langs=4, seed=7
        )
    else:
        cfg = SyntheticGraphConfig(
            n_pins=100_000, n_boards=10_000, n_topics=24, n_langs=4, seed=7
        )
    return generate(cfg)


def timed(fn, *args, warmup: int = 1, iters: int = 3) -> Dict[str, float]:
    for _ in range(warmup):
        out = fn(*args)
        jax.tree.map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x,
            out,
        )
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree.map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x,
            out,
        )
        times.append(time.perf_counter() - t0)
    return {"mean_ms": 1e3 * float(np.mean(times)),
            "min_ms": 1e3 * float(np.min(times))}


def sample_query_pins(sg: SyntheticGraph, n: int, seed: int = 0) -> np.ndarray:
    """Query pins sampled weighted by degree (active pins, like real queries)."""
    rng = np.random.default_rng(seed)
    degs = np.asarray(sg.graph.p2b.degrees()).astype(np.float64)
    p = degs / degs.sum()
    return rng.choice(sg.graph.n_pins, size=n, replace=False, p=p).astype(np.int32)
