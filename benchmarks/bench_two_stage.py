"""Fused two-stage retrieval -> ranking sweep: batch x walk backend at
fixed serving capacity, plus the backend-agreement verdict.

This suite exercises the two-stage tentpole on the serving path
(``service.serve_batch(rank=...)`` / ``serving.recommend.recommend_two_stage``):
stage 1 retrieves ``n_candidates`` per query with the batch-native fused
walk engine (or its vmapped XLA oracle twin), stage 2 gathers each
candidate's graph neighborhood, pools it with the Pallas embedding-bag,
and scores it under a per-request scenario head — ONE jitted program end
to end.

The sweep holds SERVER CAPACITY fixed — a constant total walker pool and
step budget split evenly across the batch (the bench_batchfuse framing) —
while the ranker config stays constant: stage-2 work scales with
batch x n_candidates regardless of how stage-1 capacity is split.

The agreement verdict is the regression signal: ``two_stage_backends_agree``
asserts the fused pallas path == the XLA oracle BIT-identically — stage-1
candidate ids, final ranker scores, final ordering, and the walk
telemetry — for every batch {1, 4, 16} x gather mode {scalar, dma}, with
mixed scenario heads in every batch.  Stage 2's float math is ONE shared
program for both walk backends (the bag op's lowering is
platform-defaulted, never backend-derived — kernels/ops.py), so this
parity is exact by construction; the backends diverge only inside the
integer-exact walk engines.

Kernel-launch structure is recorded from the jaxpr: a ranked serve step
keeps a CONSTANT pallas_call count independent of batch size — 2
walk-engine calls per chunk, plus 2 rank-1-grid embedding bags when
stage 2 lowers through the kernel (the TPU shape; on CPU the platform
default is the oracle bag, and the kernel-shaped lowering is traced
explicitly).  On CPU hosts the kernels run in interpret mode — ms there
measures plumbing, not kernel speed; regress on the verdict, never on
the CPU ratios.

Results land in ``results/bench.json`` AND merge into
``BENCH_serving.json`` as the ``two_stage`` section.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import merge_serving_section, timed
from repro.core import service, walk as walk_lib
from repro.graphs.synthetic import SyntheticGraphConfig, generate
from repro.kernels.introspect import pallas_grids
from repro.serving import ranker as ranker_lib

BATCHES = (1, 4, 16)
# fixed server capacity, split evenly across the batch (divisible by all
# swept batch sizes); the ranker shape below is constant across the sweep
TOTAL_WALKERS = 192
TOTAL_STEPS = 6_144


def _ranker(g, seed: int) -> ranker_lib.RankRequest:
    cfg = ranker_lib.RankerConfig(
        n_items=g.n_pins, d_model=32, n_neighbors=8,
        n_candidates=32, final_k=10,
    )
    return ranker_lib.RankRequest(
        ranker_lib.init_ranker_params(jax.random.key(seed), cfg), cfg
    )


def _batch(g, seed, batch, n_slots=2):
    rng = np.random.default_rng(seed)
    degs = np.asarray(g.p2b.degrees()).astype(np.float64)
    qs = rng.choice(g.n_pins, size=batch * n_slots, replace=False,
                    p=degs / degs.sum())
    pins = qs.reshape(batch, n_slots).astype(np.int32)
    weights = np.tile(np.asarray([1.0, 0.6], np.float32), (batch, 1))
    scen = np.arange(batch, dtype=np.int32) % 2  # mixed heads every batch
    return jnp.asarray(pins), jnp.asarray(weights), jnp.asarray(scen)


def _launch_counts(g, rank, pins, weights, feats, scen, cfg) -> Dict:
    """Kernel-launch structure of one RANKED serve step.

    Two traces: the platform-default program serve_batch actually runs
    (on CPU stage 2 lowers to the oracle bag — walk calls only), and the
    kernel-shaped stage 2 (``use_kernel=True`` — what a TPU host lowers),
    which must add exactly 2 rank-1 bag grids on top of the walk's calls.
    """
    ret_cfg = dataclasses.replace(cfg, top_k=rank.cfg.n_candidates)

    def ranked(key):
        return service.serve_batch(g, pins, weights, feats, key, cfg,
                                   backend="pallas", rank=rank,
                                   scenario=scen)

    def ranked_kernel_bags(key):
        s, i, st, nh = service.serve_batch(
            g, pins, weights, feats, key, ret_cfg, backend="pallas",
            with_stats=True,
        )
        return ranker_lib.rank_candidates(
            rank.params, rank.cfg, g, i, s, scen, use_kernel=True
        )

    dg = pallas_grids(jax.make_jaxpr(ranked)(jax.random.key(0)))
    kg = pallas_grids(jax.make_jaxpr(ranked_kernel_bags)(jax.random.key(0)))
    batch = int(pins.shape[0])
    return {
        "default_calls": len(dg),
        "kernel_bag_calls": len(kg),
        "kernel_bag_grids": [list(x) for x in kg],
        # the structural claim: no grid anywhere leads with the batch axis
        "batch_in_grid": batch > 1 and any(
            x and x[0] == batch for x in list(dg) + list(kg)
        ),
    }


def _sweep(seed: int) -> Dict:
    sg = generate(SyntheticGraphConfig(
        n_pins=1_000, n_boards=100, n_topics=8, n_langs=2, seed=seed
    ))
    g = sg.graph
    rank = _ranker(g, seed + 1)
    key = jax.random.key(seed)

    sweep = []
    agree = True
    for batch in BATCHES:
        cfg = walk_lib.WalkConfig(
            n_steps=TOTAL_STEPS // batch, n_walkers=TOTAL_WALKERS // batch,
            chunk_steps=8, top_k=20, n_p=60, n_v=3,
        )
        pins, weights, scen = _batch(g, seed, batch)
        feats = jnp.zeros((batch,), jnp.int32)
        row: Dict = {
            "batch": batch, "n_walkers_per_query": cfg.n_walkers,
            "n_steps_per_query": cfg.n_steps, "engines": {},
        }
        outs = {}

        def two_stage(backend, gather):
            ecfg = dataclasses.replace(cfg, gather_mode=gather)
            return jax.jit(lambda k: service.serve_batch(
                g, pins, weights, feats, k, ecfg, backend=backend,
                rank=rank, scenario=scen, with_stats=True,
            ))

        def retrieval_only(backend):
            ecfg = dataclasses.replace(cfg, top_k=rank.cfg.n_candidates)
            return jax.jit(lambda k: service.serve_batch(
                g, pins, weights, feats, k, ecfg, backend=backend,
            ))

        engines = {
            "xla": two_stage("xla", "scalar"),
            "pallas_scalar": two_stage("pallas", "scalar"),
            "pallas_dma": two_stage("pallas", "dma"),
        }
        for label, fn in engines.items():
            t = timed(fn, key, warmup=1, iters=2)
            scores, ids, steps, n_high = fn(key)
            outs[label] = (np.asarray(scores), np.asarray(ids),
                           np.asarray(steps), np.asarray(n_high))
            row["engines"][label] = {
                "batch_ms": round(t["mean_ms"], 2),
                "per_query_ms": round(t["mean_ms"] / batch, 3),
            }
        # stage-1 candidates agree too (not just the final ranking)
        cand = {
            label: tuple(np.asarray(x) for x in retrieval_only(b)(key))
            for label, b in (("xla", "xla"), ("pallas", "pallas"))
        }
        row["stage1_agree"] = bool(all(
            np.array_equal(a, b)
            for a, b in zip(cand["xla"], cand["pallas"])
        ))
        ref = outs["xla"]
        row["agree"] = bool(row["stage1_agree"] and all(
            np.array_equal(a, b)
            for other in ("pallas_scalar", "pallas_dma")
            for a, b in zip(ref, outs[other])
        ))
        agree &= row["agree"]
        # stage-2 overhead on the fused path, same backend
        ro = timed(retrieval_only("pallas"), key, warmup=1, iters=2)
        row["retrieval_only_batch_ms"] = round(ro["mean_ms"], 2)
        row["launch"] = _launch_counts(
            g, rank, pins, weights, feats, scen, cfg
        )
        sweep.append(row)
    # structural invariant across the sweep: ranked call counts constant
    # and batch-free, kernel-shaped stage 2 = walk calls + 2 bags
    defaults = {r["launch"]["default_calls"] for r in sweep}
    kernels = {r["launch"]["kernel_bag_calls"] for r in sweep}
    structure_ok = (
        len(defaults) == 1 and len(kernels) == 1
        and next(iter(kernels)) == 4
        and not any(r["launch"]["batch_in_grid"] for r in sweep)
    )
    return {
        "graph": {"n_pins": g.n_pins, "n_boards": g.n_boards},
        "config": {
            "total_walkers": TOTAL_WALKERS, "total_steps": TOTAL_STEPS,
            "chunk_steps": 8, "n_candidates": rank.cfg.n_candidates,
            "final_k": rank.cfg.final_k, "d_model": rank.cfg.d_model,
            "n_neighbors": rank.cfg.n_neighbors,
            "scenarios": list(rank.cfg.scenarios),
        },
        "sweep": sweep, "agree_all": agree,
        "constant_calls": structure_ok,
    }


def run(seed: int = 0) -> Dict:
    out: Dict = {
        "host_backend": jax.default_backend(),
        "pallas_interpret": jax.default_backend() == "cpu",
        "two_stage": _sweep(seed),
    }
    # verdict: the fused pallas two-stage path == the XLA oracle
    # bit-identically (candidate ids, ranker scores, final ordering,
    # telemetry) across batch x gather, AND the lowering keeps a constant
    # pallas_call count independent of batch size
    out["two_stage_backends_agree"] = bool(
        out["two_stage"]["agree_all"] and out["two_stage"]["constant_calls"]
    )
    out["wrote"] = merge_serving_section("two_stage", {
        "two_stage_backends_agree": out["two_stage_backends_agree"],
        "pallas_interpret": out["pallas_interpret"],
        "config": out["two_stage"]["config"],
        "sweep": [
            {
                "batch": row["batch"],
                "agree": row["agree"],
                "stage1_agree": row["stage1_agree"],
                "per_query_ms": {
                    k: v["per_query_ms"] for k, v in row["engines"].items()
                },
                "retrieval_only_batch_ms": row["retrieval_only_batch_ms"],
                "default_calls": row["launch"]["default_calls"],
                "kernel_bag_calls": row["launch"]["kernel_bag_calls"],
            }
            for row in out["two_stage"]["sweep"]
        ],
    })
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))
