"""Figure 4: graph pruning — link-prediction F1 and edge count vs delta.

The paper's eval: query a board's existing pins, predict the pins saved to
it later; F1 over the top-100; sweep the degree-pruning factor delta.
Claims under test: (a) edges decrease monotonically with delta, (b) an
intermediate delta beats the unpruned graph (paper: +58% F1 at delta=0.91
with ~20% of edges).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_graph
from repro.core import pruning, walk as walk_lib


def _link_pred_f1(sg, graph, n_boards_eval, seed):
    rng = np.random.default_rng(seed)
    by_board: Dict[int, list] = {}
    for p, b in zip(sg.heldout_pins, sg.heldout_boards):
        by_board.setdefault(int(b), []).append(int(p))
    boards = [b for b, pins in by_board.items() if len(pins) >= 2]
    rng.shuffle(boards)
    boards = boards[:n_boards_eval]

    b2p_off = np.asarray(graph.b2p.offsets)
    b2p_tgt = np.asarray(graph.b2p.targets)
    cfg = walk_lib.WalkConfig(
        n_steps=20_000, n_walkers=256, top_k=100, n_p=10**9, n_v=10**9
    )
    f1s = []
    for i, b in enumerate(boards):
        lo, hi = b2p_off[b], b2p_off[b + 1]
        members = b2p_tgt[lo:hi][:8]
        if members.size == 0:
            continue
        qp = jnp.full((8,), -1, jnp.int32).at[: members.size].set(
            jnp.asarray(members)
        )
        qw = jnp.zeros((8,), jnp.float32).at[: members.size].set(1.0)
        vals, ids = walk_lib.recommend(
            graph, qp, qw, jnp.asarray(0, jnp.int32),
            jax.random.key(seed + i), cfg,
        )
        r = set(np.asarray(ids)[np.asarray(vals) > 0].tolist())
        x = set(by_board[b])
        tp = len(r & x)
        prec = tp / max(len(r), 1)
        rec = tp / max(len(x), 1)
        f1s.append(2 * prec * rec / max(prec + rec, 1e-9))
    return float(np.mean(f1s)) if f1s else 0.0


def run(n_boards_eval: int = 20, seed: int = 0) -> Dict:
    sg = bench_graph()
    out = {"sweep": []}
    for delta in (1.0, 0.95, 0.9, 0.8, 0.65):
        cfg = pruning.PruneConfig(entropy_board_frac=0.10, delta=delta)
        pruned, stats = pruning.prune_graph(
            sg.graph, sg.pin_topics, None, cfg,
            board_lang=sg.board_lang, pin_lang=sg.pin_lang,
            n_langs=4,
        )
        f1 = _link_pred_f1(sg, pruned, n_boards_eval, seed)
        out["sweep"].append({
            "delta": delta,
            "edges": stats["edges_after"],
            "edge_keep_frac": round(stats["edge_keep_frac"], 3),
            "f1": round(f1, 4),
        })
    rows = out["sweep"]
    out["edges_monotone_in_delta"] = bool(
        all(rows[i]["edges"] >= rows[i + 1]["edges"]
            for i in range(len(rows) - 1))
    )
    base_f1 = rows[0]["f1"]
    best = max(rows, key=lambda r: r["f1"])
    out["pruning_improves_f1"] = bool(best["f1"] >= base_f1)
    out["best"] = best
    out["f1_lift_at_best"] = round(
        (best["f1"] - base_f1) / max(base_f1, 1e-9), 3
    )
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))
