"""Benchmark driver: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # full suite
  PYTHONPATH=src python -m benchmarks.run --only fig4

Writes results/bench.json and prints a summary with the per-claim
reproduction verdicts.
"""

from __future__ import annotations

import argparse
import json
import os
import time

from benchmarks import (
    bench_batchfuse,
    bench_chaos,
    bench_dma_gather,
    bench_earlystop_fused,
    bench_fig1_runtime,
    bench_fig2_stability,
    bench_fig3_earlystop,
    bench_fig4_pruning,
    bench_fig5_memory,
    bench_multi_interest,
    bench_serving,
    bench_sharded,
    bench_smoke,
    bench_table1_hitrate,
    bench_table3_bias,
    bench_traffic,
    bench_two_stage,
    bench_widepack,
)

SUITES = {
    "table1": ("Table 1: hit-rate vs content baselines",
               bench_table1_hitrate.run),
    "table3": ("Table 3: biased-walk language lift", bench_table3_bias.run),
    "fig1": ("Fig 1: runtime vs steps / query size", bench_fig1_runtime.run),
    "fig2": ("Fig 2: stability vs steps", bench_fig2_stability.run),
    "fig3": ("Fig 3: early stopping", bench_fig3_earlystop.run),
    "fig4": ("Fig 4: pruning link-prediction F1", bench_fig4_pruning.run),
    "fig5": ("Fig 5: memory/runtime vs pruning", bench_fig5_memory.run),
    "serving": ("Serving fleet QPS/latency (§3.3)", bench_serving.run),
    "smoke": ("Serving smoke: xla vs pallas walk engines -> "
              "BENCH_serving.json", bench_smoke.run),
    "earlystop_fused": ("Fused in-VMEM early-stop tally vs full re-histogram",
                        bench_earlystop_fused.run),
    "widepack": ("Wide (slot, pin) lanes: id spaces past 2**31 + "
                 "incremental event checks", bench_widepack.run),
    "dma_gather": ("Double-buffered async-DMA CSR prefetch vs scalar "
                   "gathers", bench_dma_gather.run),
    "batchfuse": ("Batch-native fused walk engine: one Pallas program per "
                  "chunk for the whole query batch", bench_batchfuse.run),
    "sharded": ("Pod-sharded batched fused walk engine: per-shard "
                "supersteps on the bounded routing fabric",
                bench_sharded.run),
    "traffic": ("Continuous-traffic serving: bucketed deadline-aware "
                "batches under an open-loop Poisson load generator",
                bench_traffic.run),
    "two_stage": ("Fused two-stage retrieval -> ranking: batched walk + "
                  "embedding-bag neighborhoods + scenario heads",
                  bench_two_stage.run),
    "multi_interest": ("Multi-interest users: clustered queries as budgeted "
                       "lanes on the batch axis + Eq. 3 cross-cluster merge",
                       bench_multi_interest.run),
    "chaos": ("Degraded-mode serving: elastic shed budgets, dead-shard "
              "tolerance, seeded fault injection", bench_chaos.run),
}

VERDICT_KEYS = (
    "ordering_reproduced", "bias_lift_reproduced", "near_linear",
    "query_size_sublinear", "stability_grows_with_steps",
    "early_stop_saves_steps", "edges_monotone_in_delta",
    "pruning_improves_f1", "memory_decreases", "batching_overhead_bounded",
    "both_backends_agree", "fused_matches_naive", "earlystop_backends_agree",
    "widepack_backends_agree", "incremental_matches_full",
    "dma_backends_agree", "batch_engine_agrees", "sharded_engine_agrees",
    "traffic_buckets_agree", "two_stage_backends_agree",
    "multi_interest_agrees", "degraded_serving_agrees",
)


def _flatten(d, prefix=""):
    for k, v in d.items():
        if isinstance(v, dict):
            yield from _flatten(v, prefix + k + ".")
        else:
            yield k, v


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--out", default="results/bench.json")
    ap.add_argument("--in-process", action="store_true",
                    help="run suites in this process (default: one "
                    "subprocess per suite — XLA CPU JIT memory accumulates "
                    "across suites otherwise)")
    args = ap.parse_args(argv)

    names = args.only or list(SUITES)

    if not args.in_process and len(names) > 1:
        import subprocess
        import sys

        results = {}
        os.makedirs("results/bench_parts", exist_ok=True)
        rc_all = 0
        for name in names:
            part = f"results/bench_parts/{name}.json"
            rc = subprocess.run(
                [sys.executable, "-m", "benchmarks.run", "--in-process",
                 "--only", name, "--out", part],
            ).returncode
            rc_all |= rc
            try:
                with open(part) as f:
                    results.update(json.load(f))
            except Exception as e:
                results[name] = {"error": f"subprocess failed: {e}"}
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
        n_claims = n_ok = 0
        for res in results.values():
            for k, v in _flatten(res):
                if k in VERDICT_KEYS:
                    n_claims += 1
                    n_ok += bool(v)
        print(f"\nwrote {args.out}")
        print(f"paper-claim verdicts: {n_ok}/{n_claims} reproduced")
        return 0 if (n_ok == n_claims and not rc_all) else 1

    results = {}
    n_errors = 0
    for name in names:
        title, fn = SUITES[name]
        t0 = time.time()
        print(f"== {title} ==", flush=True)
        try:
            res = fn()
            res["_seconds"] = round(time.time() - t0, 1)
            results[name] = res
            verdicts = {
                k: v for k, v in _flatten(res) if k in VERDICT_KEYS
            }
            print(json.dumps(verdicts), f"({res['_seconds']}s)", flush=True)
        except Exception as e:  # record, keep going
            n_errors += 1
            results[name] = {"error": f"{type(e).__name__}: {e}"}
            print("FAILED:", results[name]["error"], flush=True)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"\nwrote {args.out}")

    n_claims = n_ok = 0
    for res in results.values():
        for k, v in _flatten(res):
            if k in VERDICT_KEYS:
                n_claims += 1
                n_ok += bool(v)
    print(f"paper-claim verdicts: {n_ok}/{n_claims} reproduced"
          + (f" ({n_errors} suite(s) crashed)" if n_errors else ""))
    # a crashed suite contributes no verdicts — it must not look like a pass
    return 0 if (n_ok == n_claims and n_errors == 0) else 1


if __name__ == "__main__":
    import sys

    sys.exit(main())
