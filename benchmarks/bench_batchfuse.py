"""Batch-native fused walk engine sweep: one Pallas program per chunk for
the whole query batch vs the vmapped per-query formulation.

Quantifies the batching tentpole on the serving path
(``core/service.serve_batch``): ``backend="pallas"`` routes through
``core/walk.pixie_random_walk_batched`` — all queries' walkers packed on
one walker axis, ONE fused ``pallas_call`` + ONE query-major counting call
per superstep chunk, a shared while loop with a per-(query, slot)
early-stop mask — swept over batch {1, 4, 16, 64} x gather mode, with two
controls: the vmapped per-query XLA engine (serve_batch's
``backend="xla"`` twin) and the vmapped per-query *pallas* engine (what
serve_batch used to do: vmap prepends the batch to every kernel grid).

The sweep holds SERVER CAPACITY fixed — a constant total walker pool and
step budget split evenly across the batch (the paper's serving framing: a
64-core machine amortizes over concurrent queries) — so "per-query ms vs
batch" is a real amortization curve and the dense count space
(batch x n_slots x n_pins bins) stays affordable under CPU interpret.

The agreement verdict is the regression signal: ``batch_engine_agrees``
asserts batched == vmapped bit-identically — ids, scores, and the
early-stop observables (steps_taken, n_high) — for every batch size and
gather mode.  Kernel-launch structure is recorded from the jaxpr: the
batched path keeps a CONSTANT number of pallas_call eqns with no
batch-sized grid dim (one program per chunk); the vmapped control's grids
lead with the batch axis (batch x chunks replication).  On CPU hosts the
kernels run in interpret mode — per-query ms there measures plumbing, not
kernel speed; regress on ``batch_engine_agrees``, not the CPU ratios.

Results land in ``results/bench.json`` AND merge into
``BENCH_serving.json`` as the ``batchfuse`` section.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import merge_serving_section, timed
from repro.core import service, walk as walk_lib
from repro.graphs.synthetic import SyntheticGraphConfig, generate
from repro.kernels.introspect import pallas_grids

BATCHES = (1, 4, 16, 64)
# fixed server capacity, split evenly across the batch (divisible by all
# swept batch sizes): every row runs the same max_chunks and emits the
# same total events per chunk, only the batch axis changes shape
TOTAL_WALKERS = 256
TOTAL_STEPS = 8_192


def _batch(g, seed, batch, n_slots=2):
    rng = np.random.default_rng(seed)
    degs = np.asarray(g.p2b.degrees()).astype(np.float64)
    qs = rng.choice(g.n_pins, size=batch * n_slots, replace=False,
                    p=degs / degs.sum())
    pins = qs.reshape(batch, n_slots).astype(np.int32)
    weights = np.tile(np.asarray([1.0, 0.6], np.float32), (batch, 1))
    return jnp.asarray(pins), jnp.asarray(weights)


def _launch_counts(g, pins, weights, feats, cfg) -> Dict:
    """Kernel-launch structure of one serve step, batched vs vmapped."""
    batch = int(pins.shape[0])

    def batched(key):
        return service.serve_batch(g, pins, weights, feats, key, cfg,
                                   backend="pallas")

    def vmapped(keys):
        pcfg = dataclasses.replace(cfg, backend="pallas")
        return jax.vmap(
            lambda qp, qw, uf, k: walk_lib.recommend_with_stats(
                g, qp, qw, uf, k, pcfg
            )
        )(pins, weights, feats, keys)

    bg = pallas_grids(jax.make_jaxpr(batched)(jax.random.key(0)))
    vg = pallas_grids(
        jax.make_jaxpr(vmapped)(jax.random.split(jax.random.key(0), batch))
    )
    return {
        # pallas_call eqns per while-loop body (x max_chunks trips/serve)
        "batched_calls_per_chunk": len(bg),
        "vmapped_calls_per_chunk": len(vg),
        "batched_grids": [list(x) for x in bg],
        "vmapped_grids": [list(x) for x in vg],
        # the structural claim: no batch-sized leading grid dim vs all
        # (only meaningful past batch 1 — vmap over a size-1 batch is a
        # no-op on the grid shape)
        "batched_batch_in_grid": any(x and x[0] == batch for x in bg)
        and batch > 1,
        "vmapped_batch_in_grid": batch > 1
        and all(x and x[0] == batch for x in vg),
        "max_chunks": cfg.max_chunks(),
    }


def _sweep(seed: int) -> Dict:
    sg = generate(SyntheticGraphConfig(
        n_pins=1_000, n_boards=100, n_topics=8, n_langs=2, seed=seed
    ))
    g = sg.graph
    key = jax.random.key(seed)

    sweep = []
    agree = True
    for batch in BATCHES:
        cfg = walk_lib.WalkConfig(
            n_steps=TOTAL_STEPS // batch, n_walkers=TOTAL_WALKERS // batch,
            chunk_steps=8, top_k=20, n_p=60, n_v=3,
        )
        pins, weights = _batch(g, seed, batch)
        feats = jnp.zeros((batch,), jnp.int32)
        keys = jax.random.split(key, batch)
        row: Dict = {"batch": batch, "n_walkers_per_query": cfg.n_walkers,
                     "n_steps_per_query": cfg.n_steps, "engines": {}}
        outs = {}

        def serve(backend, gather):
            ecfg = dataclasses.replace(cfg, gather_mode=gather)
            return jax.jit(lambda k: service.serve_batch(
                g, pins, weights, feats, k, ecfg, backend=backend,
                with_stats=True,
            ))

        def vmapped_pallas():
            pcfg = dataclasses.replace(cfg, backend="pallas")
            return jax.jit(lambda ks: jax.vmap(
                lambda qp, qw, uf, k: walk_lib.recommend_with_stats(
                    g, qp, qw, uf, k, pcfg
                )
            )(pins, weights, feats, ks))

        engines = {
            "xla_vmapped": (serve("xla", "scalar"), key),
            "pallas_batched_scalar": (serve("pallas", "scalar"), key),
            "pallas_batched_dma": (serve("pallas", "dma"), key),
            "pallas_vmapped": (vmapped_pallas(), keys),
        }
        for label, (fn, arg) in engines.items():
            t = timed(fn, arg, warmup=1, iters=2)
            scores, ids, steps, n_high = fn(arg)
            outs[label] = (np.asarray(scores), np.asarray(ids),
                           np.asarray(steps), np.asarray(n_high))
            row["engines"][label] = {
                "batch_ms": round(t["mean_ms"], 2),
                "per_query_ms": round(t["mean_ms"] / batch, 3),
            }
        ref_out = outs["xla_vmapped"]
        row["agree"] = bool(all(
            np.array_equal(a, b)
            for other in ("pallas_batched_scalar", "pallas_batched_dma",
                          "pallas_vmapped")
            for a, b in zip(ref_out, outs[other])
        ))
        agree &= row["agree"]
        row["batched_vs_vmapped_pallas_x"] = round(
            row["engines"]["pallas_vmapped"]["batch_ms"]
            / max(row["engines"]["pallas_batched_scalar"]["batch_ms"], 1e-9),
            3,
        )
        row["launch"] = _launch_counts(g, pins, weights, feats, cfg)
        sweep.append(row)
    # structural invariant across the sweep: batched call count constant
    # and batch-free, vmapped grids batch-replicated
    calls = {r["launch"]["batched_calls_per_chunk"] for r in sweep}
    structure_ok = (
        len(calls) == 1
        and not any(r["launch"]["batched_batch_in_grid"] for r in sweep)
        and all(r["launch"]["vmapped_batch_in_grid"] for r in sweep
                if r["batch"] > 1)
    )
    return {"graph": {"n_pins": g.n_pins, "n_boards": g.n_boards},
            "config": {"total_walkers": TOTAL_WALKERS,
                       "total_steps": TOTAL_STEPS, "chunk_steps": 8},
            "sweep": sweep, "agree_all": agree,
            "one_call_per_chunk": structure_ok}


def run(seed: int = 0) -> Dict:
    out: Dict = {
        "host_backend": jax.default_backend(),
        "pallas_interpret": jax.default_backend() == "cpu",
        "batchfuse": _sweep(seed),
    }
    # verdict: batched engine == vmapped per-query path bit-identically
    # (ids, scores, steps_taken, n_high) AND the lowering really is one
    # program per chunk, independent of batch size
    out["batch_engine_agrees"] = bool(
        out["batchfuse"]["agree_all"] and out["batchfuse"]["one_call_per_chunk"]
    )
    out["wrote"] = merge_serving_section("batchfuse", {
        "batch_engine_agrees": out["batch_engine_agrees"],
        "pallas_interpret": out["pallas_interpret"],
        "sweep": [
            {
                "batch": row["batch"],
                "agree": row["agree"],
                "per_query_ms": {
                    k: v["per_query_ms"] for k, v in row["engines"].items()
                },
                "batched_calls_per_chunk":
                    row["launch"]["batched_calls_per_chunk"],
                "vmapped_batch_in_grid":
                    row["launch"]["vmapped_batch_in_grid"],
            }
            for row in out["batchfuse"]["sweep"]
        ],
    })
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))
