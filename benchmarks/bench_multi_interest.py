"""Multi-interest serving sweep: users x clusters x walk backend at fixed
serving capacity, plus the fused-vs-oracle agreement verdict.

This suite exercises the multi-interest tentpole end to end
(``service.build_user_query`` -> ``batch_user_queries`` ->
``recommend.recommend_multi_interest``): every user's action history is
clustered host-side into k interest clusters (PinnerSage-style
agglomeration over pin topic vectors), each cluster becomes a weighted
query lane with its own Eq. 2 step budget (importance-proportional,
riding the batch as DATA, never shape), all lanes run in ONE batched
walk, and per-user results merge with the bit-reproducible Eq. 3
cross-cluster booster (``walk.merge_interest_topk``).

The sweep holds SERVER CAPACITY fixed — a constant total step budget
split across users (each user then splits its share across clusters by
importance) — so the users x k grid isolates the cost of multi-interest
fan-out at constant work.

The agreement verdict is the regression signal: ``multi_interest_agrees``
asserts, for users {1, 4, 16} x k {1, 2, 4} x backend {xla, pallas} x
gather {scalar, dma}:

  * the fused path == the per-cluster ORACLE (independent single-query
    walks, each with its cluster's budget, merged host-side by the same
    jitted merge at the live-k shape) BIT-identically;
  * k=1 collapses EXACTLY to the flat homefeed ``serve_batch`` path;
  * the ``pallas_call`` count of a multi-interest serve step is CONSTANT
    as k grows — cluster lanes add rows on the PR 5 query axis, never
    kernel launches (jaxpr-pinned).

On CPU hosts the kernels run in interpret mode — ms there measures
plumbing, not kernel speed; regress on the verdict, never on CPU ratios.

Results land in ``results/bench.json`` AND merge into
``BENCH_serving.json`` as the ``multi_interest`` section.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import merge_serving_section, timed
from repro.core import service, walk as walk_lib
from repro.graphs import synthetic
from repro.kernels.introspect import pallas_grids
from repro.serving.recommend import recommend_multi_interest

USERS = (1, 4, 16)
CLUSTERS = (1, 2, 4)
# fixed per-user capacity; a user's clusters split this by importance
STEPS_PER_USER = 768
WALKERS = 32
N_SLOTS = 8


def _user_batches(sg, seed: int) -> Dict:
    """One shared pool of seeded histories; each (users, k) cell reuses a
    prefix so the sweep varies load, not workload identity."""
    hist_cfg = synthetic.UserHistoryConfig(
        n_users=max(USERS), n_interests=3, mean_actions=16, seed=seed
    )
    return synthetic.sample_user_histories(sg, hist_cfg)


def _batch_for(sg, histories, n_users: int, k: int):
    uqs = [
        service.build_user_query(
            h.actions, sg.pin_topics, n_slots=N_SLOTS, n_clusters=k
        )
        for h in histories[:n_users]
    ]
    return service.batch_user_queries(uqs, n_steps=STEPS_PER_USER), uqs


def _oracle(g, batch, uqs, lane_keys, cfg):
    """Per-cluster single-query walks merged host-side at the live-k
    shape — the independent twin the fused path must reproduce bitwise."""
    single = jax.jit(
        lambda qp, qw, uf, kk, sb: walk_lib.recommend_with_stats(
            g, qp, qw, uf, kk, cfg, step_budget=sb
        )
    )
    merge = jax.jit(walk_lib.merge_interest_topk)
    lane_of_user = np.asarray(batch.lane_of_user)
    out_s, out_i = [], []
    for u, uq in enumerate(uqs):
        lanes = lane_of_user[u][lane_of_user[u] >= 0]
        ss, ii = zip(*[
            single(
                batch.pins[li], batch.weights[li], batch.feats[li],
                lane_keys[li], batch.step_budgets[li],
            )[:2]
            for li in lanes
        ])
        ms, mi = merge(jnp.stack(ss), jnp.stack(ii),
                       jnp.asarray(uq.importance))
        out_s.append(np.asarray(ms))
        out_i.append(np.asarray(mi))
    return np.stack(out_s), np.stack(out_i)


def _launch_counts(g, batch, cfg) -> Dict:
    n_lanes = int(batch.pins.shape[0])

    def step(key):
        return recommend_multi_interest(
            g, batch, jax.random.split(key, n_lanes), cfg
        )

    grids = pallas_grids(jax.make_jaxpr(step)(jax.random.key(0)))
    return {
        "calls": len(grids),
        "lanes_in_grid": n_lanes > 1 and any(
            x and x[0] == n_lanes for x in grids
        ),
    }


def _sweep(seed: int) -> Dict:
    sg = synthetic.generate(synthetic.SyntheticGraphConfig(
        n_pins=1_000, n_boards=100, n_topics=8, n_langs=2, seed=seed
    ))
    g = sg.graph
    histories = _user_batches(sg, seed + 1)
    base_cfg = walk_lib.WalkConfig(
        n_steps=STEPS_PER_USER, n_walkers=WALKERS, chunk_steps=8,
        top_k=16, n_p=60, n_v=3,
    )

    sweep = []
    agree = True
    pallas_calls = set()
    for n_users in USERS:
        for k in CLUSTERS:
            batch, uqs = _batch_for(sg, histories, n_users, k)
            n_lanes = int(batch.pins.shape[0])
            lane_keys = jax.random.split(jax.random.key(seed), n_lanes)
            row: Dict = {
                "users": n_users, "k": k, "lanes": n_lanes, "engines": {},
            }
            outs = {}
            engines = {
                "xla": ("xla", "scalar"),
                "pallas_scalar": ("pallas", "scalar"),
                "pallas_dma": ("pallas", "dma"),
            }
            for label, (backend, gather) in engines.items():
                ecfg = dataclasses.replace(
                    base_cfg, backend=backend, gather_mode=gather
                )
                fn = jax.jit(lambda ks, b=batch, c=ecfg:
                             recommend_multi_interest(g, b, ks, c))
                t = timed(fn, lane_keys, warmup=1, iters=2)
                ms, mi = fn(lane_keys)
                outs[label] = (np.asarray(ms), np.asarray(mi))
                row["engines"][label] = {
                    "batch_ms": round(t["mean_ms"], 2),
                    "per_user_ms": round(t["mean_ms"] / n_users, 3),
                }
            # fused engines agree with each other...
            ref = outs["xla"]
            row["backends_agree"] = bool(all(
                np.array_equal(a, b)
                for other in ("pallas_scalar", "pallas_dma")
                for a, b in zip(ref, outs[other])
            ))
            # ...and with the per-cluster oracle, bit for bit
            os_, oi = _oracle(g, batch, uqs, lane_keys, base_cfg)
            row["oracle_agree"] = bool(
                np.array_equal(ref[0].view(np.uint32), os_.view(np.uint32))
                and np.array_equal(ref[1], oi)
            )
            # k=1 is the flat homefeed path, verbatim
            if k == 1:
                fs, fi = service.serve_batch(
                    g, batch.pins, batch.weights, batch.feats, lane_keys,
                    base_cfg,
                )
                row["flat_collapse"] = bool(
                    np.array_equal(ref[0].view(np.uint32),
                                   np.asarray(fs).view(np.uint32))
                    and np.array_equal(ref[1], np.asarray(fi))
                )
            launch = _launch_counts(
                g, batch, dataclasses.replace(base_cfg, backend="pallas")
            )
            row["pallas_calls"] = launch["calls"]
            pallas_calls.add(launch["calls"])
            row["agree"] = bool(
                row["backends_agree"] and row["oracle_agree"]
                and row.get("flat_collapse", True)
                and not launch["lanes_in_grid"]
            )
            agree &= row["agree"]
            sweep.append(row)
    # the pin has teeth only if the pallas lowering actually launches
    constant_calls = pallas_calls == {2}
    return {
        "graph": {"n_pins": g.n_pins, "n_boards": g.n_boards},
        "config": {
            "steps_per_user": STEPS_PER_USER, "walkers": WALKERS,
            "n_slots": N_SLOTS, "users": list(USERS),
            "clusters": list(CLUSTERS),
        },
        "sweep": sweep, "agree_all": agree,
        "constant_calls": constant_calls,
    }


def run(seed: int = 0) -> Dict:
    out: Dict = {
        "host_backend": jax.default_backend(),
        "pallas_interpret": jax.default_backend() == "cpu",
        "multi_interest": _sweep(seed),
    }
    # verdict: fused multi-interest serving == the per-cluster oracle
    # bit-identically across users x k x backend x gather, k=1 collapses
    # exactly to the flat path, and clusters add lanes, never launches
    out["multi_interest_agrees"] = bool(
        out["multi_interest"]["agree_all"]
        and out["multi_interest"]["constant_calls"]
    )
    out["wrote"] = merge_serving_section("multi_interest", {
        "multi_interest_agrees": out["multi_interest_agrees"],
        "pallas_interpret": out["pallas_interpret"],
        "config": out["multi_interest"]["config"],
        "sweep": [
            {
                "users": row["users"], "k": row["k"], "lanes": row["lanes"],
                "agree": row["agree"],
                "oracle_agree": row["oracle_agree"],
                "backends_agree": row["backends_agree"],
                **({"flat_collapse": row["flat_collapse"]}
                   if "flat_collapse" in row else {}),
                "pallas_calls": row["pallas_calls"],
                "per_user_ms": {
                    kk: v["per_user_ms"] for kk, v in row["engines"].items()
                },
            }
            for row in out["multi_interest"]["sweep"]
        ],
    })
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))
