"""Wide-pack sweep: production-scale id spaces + incremental event checks.

Two levels, mirroring the wide-lane tentpole:

1. **Scale sweep** — event-mode walks over graphs whose packed
   ``slot * n_pins + pin`` id space spans from comfortably-int32 to PAST
   2**31 (the regime that used to force the xla fallback), xla vs pallas,
   asserting bit-identical lane buffers / n_high / steps_taken / top-k
   (``widepack_backends_agree``).  The >= 2**31 rows are the paper's 3B-pin
   operating point in miniature: huge id space, bounded event memory.
2. **Check-mode micro-bench** — the same walk with
   ``check_mode="incremental"`` (fold only the new window's events into
   sorted runs) vs ``check_mode="full"`` (re-sort the whole buffer each
   check), asserting bit-identical outputs (``incremental_matches_full``)
   and recording the timing ratio.

Results are returned for ``results/bench.json`` AND merged into
``BENCH_serving.json`` as the ``widepack`` section, so the serving
trajectory file carries the scale verdicts next to the backend-agreement
ones.  On CPU hosts the Pallas kernels run in interpret mode — regress on
the agreement verdicts, not the CPU ratios.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import merge_serving_section, timed
from repro.core import walk as walk_lib
from repro.graphs.synthetic import sparse_wide_graph as _sparse_wide_graph


def _query(n_slots):
    qp = np.full((n_slots,), -1, np.int32)
    qw = np.zeros((n_slots,), np.float32)
    qp[0], qp[1] = 3, 17
    qw[0], qw[1] = 1.0, 0.5
    return jnp.asarray(qp), jnp.asarray(qw)


def _scale_sweep(seed: int) -> Dict:
    """xla vs pallas across id-space scales, incl. past the old int32 cliff."""
    shapes = (
        # (n_slots, n_pins): packed id space n_slots * n_pins
        (8, 40_000),            # 3.2e5 — benchmark scale
        (4_096, 40_000),        # 1.6e8 — large but int32-packable
        (65_536, 40_000),       # 2.6e9 — PAST 2**31: the old fallback regime
    )
    cfg = walk_lib.WalkConfig(
        n_steps=1_024, n_walkers=64, chunk_steps=4, n_p=500, n_v=3,
        bias_beta=0.0,
    )
    key = jax.random.key(seed)
    rows = []
    agree = True
    for n_slots, n_pins in shapes:
        g = _sparse_wide_graph(
            seed, n_pins=n_pins, n_boards=64, n_edges=4_000, hot_pins=2_000
        )
        qp, qw = _query(n_slots)
        row: Dict = {
            "n_slots": n_slots,
            "n_pins": n_pins,
            "packed_ids": n_slots * n_pins,
            "past_int32": bool(n_slots * n_pins >= 2**31),
            "backends": {},
        }
        outs = {}
        for backend in ("xla", "pallas"):
            bcfg = dataclasses.replace(cfg, backend=backend)

            def fn(k, bcfg=bcfg, g=g, qp=qp, qw=qw, ns=n_slots, npn=n_pins):
                r = walk_lib.pixie_walk_events(
                    g, qp, qw, jnp.asarray(0, jnp.int32), k, bcfg,
                    check_every=2,
                )
                s, i = walk_lib.recommend_from_events(r, ns, npn, qp, 20)
                return r, s, i

            t = timed(lambda k, fn=fn: fn(k)[1], key, warmup=1, iters=2)
            r, s, i = fn(key)
            outs[backend] = tuple(
                np.asarray(x) for x in (*r, s, i)
            )
            row["backends"][backend] = {"walk_ms": round(t["mean_ms"], 2)}
        row_agree = all(
            np.array_equal(a, b)
            for a, b in zip(outs["xla"], outs["pallas"])
        )
        agree &= row_agree
        row["agree"] = bool(row_agree)
        rows.append(row)
    # verdict key lives only at the suite top level (run.py counts every
    # occurrence of a verdict key, at any nesting)
    return {"sweep": rows, "agree_all": bool(agree)}


def _check_mode_bench(seed: int) -> Dict:
    """Incremental window-fold vs full-buffer re-sort in the check body."""
    g = _sparse_wide_graph(
        seed + 1, n_pins=4_000, n_boards=64, n_edges=8_000, hot_pins=1_500
    )
    n_slots = 8
    qp, qw = _query(n_slots)
    cfg = walk_lib.WalkConfig(
        n_steps=16_384, n_walkers=128, chunk_steps=4, n_p=400, n_v=3,
        bias_beta=0.0,
    )
    key = jax.random.key(seed)
    out: Dict = {"modes": {}, "max_events": cfg.max_chunks()
                 * cfg.n_walkers * cfg.chunk_steps}
    results = {}
    for mode in ("incremental", "full"):

        def fn(k, mode=mode):
            return walk_lib.pixie_walk_events(
                g, qp, qw, jnp.asarray(0, jnp.int32), k, cfg,
                check_every=2, check_mode=mode,
            )

        t = timed(lambda k, fn=fn: fn(k).n_high, key, warmup=1, iters=3)
        results[mode] = tuple(np.asarray(x) for x in fn(key))
        out["modes"][mode] = {"walk_ms": round(t["mean_ms"], 2)}
    out["matches"] = bool(
        all(
            np.array_equal(a, b)
            for a, b in zip(results["incremental"], results["full"])
        )
    )
    out["incremental_speedup_x"] = round(
        out["modes"]["full"]["walk_ms"]
        / max(out["modes"]["incremental"]["walk_ms"], 1e-9),
        3,
    )
    return out


def run(seed: int = 0) -> Dict:
    out: Dict = {
        "host_backend": jax.default_backend(),
        "pallas_interpret": jax.default_backend() == "cpu",
        "scale": _scale_sweep(seed),
        "check_mode": _check_mode_bench(seed),
    }
    # surface the two verdicts at the suite's top level for the driver
    out["widepack_backends_agree"] = out["scale"]["agree_all"]
    out["incremental_matches_full"] = out["check_mode"]["matches"]
    # merge into the serving trajectory file so the scale verdicts live
    # next to the backend-agreement ones (bench_smoke writes the base file)
    out["wrote"] = merge_serving_section("widepack", {
        "widepack_backends_agree": out["widepack_backends_agree"],
        "incremental_matches_full": out["incremental_matches_full"],
        "incremental_speedup_x": out["check_mode"]["incremental_speedup_x"],
        "scales": [
            {k: row[k] for k in
             ("n_slots", "n_pins", "packed_ids", "past_int32", "agree")}
            for row in out["scale"]["sweep"]
        ],
    })
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))
