"""Early-stop counting sweep: fused in-VMEM tally vs full re-histogram.

Quantifies the tentpole of the fused early-stop path on two levels:

1. **Counting micro-bench** — the per-while-iteration cost of the dense
   engine's counting step, old formulation (accumulate the chunk, then
   recount ``n_high`` by reducing the whole ``n_slots * n_pins`` buffer)
   vs the fused API (``accumulate_packed_events_with_high`` carries the
   tally incrementally), on both counting engines.  This is the exact
   computation Algorithm 3 runs between chunks at serving time.
2. **Walk sweep** — full ``pixie_random_walk`` with early stopping active
   across (n_v, n_p) thresholds, xla vs pallas, checking the engines stay
   bit-identical on counts / n_high / steps_taken and recording timings.

On CPU hosts the Pallas numbers run in interpret mode (plumbing, not kernel
speed) — regress on the agreement verdicts, not the CPU ratios.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.core import counter as counter_lib
from repro.core import walk as walk_lib
from repro.graphs.synthetic import SyntheticGraphConfig, generate


def _counting_microbench(seed: int) -> Dict:
    """One dense-loop counting iteration: old recount vs fused tally."""
    n_slots, n_pins, n_v = 8, 20_000, 4
    n_events = 8 * 512  # chunk_steps * n_walkers worth of wide events
    kc, ks, ke = jax.random.split(jax.random.key(seed), 3)
    counts = jax.random.randint(
        kc, (n_slots * n_pins,), 0, n_v + 1, dtype=jnp.int32
    )
    slot_ev = jax.random.randint(
        ks, (n_events,), 0, n_slots + 1, dtype=jnp.int32
    )
    pin_ev = jax.random.randint(ke, (n_events,), 0, n_pins, dtype=jnp.int32)
    high = counter_lib.n_high_visited(counts.reshape(n_slots, n_pins), n_v)

    out: Dict = {"n_slots": n_slots, "n_pins": n_pins,
                 "n_events": n_events, "paths": {}}
    agree = True
    for backend in ("xla", "pallas"):

        @jax.jit
        def old_path(c, s, p, backend=backend):
            c2 = counter_lib.accumulate_packed_events(
                c, s, p, n_slots, n_pins, backend
            )
            return c2, counter_lib.n_high_visited(
                c2.reshape(n_slots, n_pins), n_v
            )

        @jax.jit
        def fused_path(c, h, s, p, backend=backend):
            return counter_lib.accumulate_packed_events_with_high(
                c, h, s, p, n_slots, n_pins, n_v, backend
            )

        t_old = timed(old_path, counts, slot_ev, pin_ev, warmup=1, iters=5)
        t_new = timed(
            fused_path, counts, high, slot_ev, pin_ev, warmup=1, iters=5
        )
        c_old, h_old = old_path(counts, slot_ev, pin_ev)
        c_new, h_new = fused_path(counts, high, slot_ev, pin_ev)
        agree &= bool(
            np.array_equal(np.asarray(c_old), np.asarray(c_new))
            and np.array_equal(np.asarray(h_old), np.asarray(h_new))
        )
        out["paths"][backend] = {
            "recount_ms": round(t_old["mean_ms"], 3),
            "fused_ms": round(t_new["mean_ms"], 3),
            "fused_speedup_x": round(
                t_old["mean_ms"] / max(t_new["mean_ms"], 1e-9), 3
            ),
        }
    out["fused_matches_naive"] = agree
    return out


def _walk_sweep(seed: int) -> Dict:
    sg = generate(SyntheticGraphConfig(
        n_pins=4_000, n_boards=400, n_topics=8, n_langs=2, seed=seed
    ))
    g = sg.graph
    degs = np.asarray(g.p2b.degrees())
    q = int(np.argmax(degs))
    qp = jnp.asarray([q], jnp.int32)
    qw = jnp.ones((1,), jnp.float32)
    base = walk_lib.WalkConfig(
        n_steps=8_000, n_walkers=256, chunk_steps=8, bias_beta=0.0
    )
    key = jax.random.key(seed)

    sweep = []
    agree = True
    for n_v, n_p in ((2, 200), (4, 500), (4, 2_000)):
        cfg = dataclasses.replace(base, n_v=n_v, n_p=n_p)
        row: Dict = {"n_v": n_v, "n_p": n_p, "backends": {}}
        results = {}
        for backend in ("xla", "pallas"):
            bcfg = dataclasses.replace(cfg, backend=backend)

            def fn(k, bcfg=bcfg):
                return walk_lib.pixie_random_walk(g, qp, qw,
                                                  jnp.asarray(0, jnp.int32),
                                                  k, bcfg)

            t = timed(fn, key, warmup=1, iters=2)
            res = fn(key)
            results[backend] = res
            row["backends"][backend] = {"walk_ms": round(t["mean_ms"], 2)}
        rx, rp = results["xla"], results["pallas"]
        agree &= bool(
            np.array_equal(np.asarray(rx.counts), np.asarray(rp.counts))
            and np.array_equal(np.asarray(rx.n_high), np.asarray(rp.n_high))
            and np.array_equal(
                np.asarray(rx.steps_taken), np.asarray(rp.steps_taken)
            )
        )
        row["steps_taken"] = int(np.asarray(rx.steps_taken)[0])
        row["n_high"] = int(np.asarray(rx.n_high)[0])
        sweep.append(row)
    # tighter thresholds must stop earlier AND the tight row must actually
    # fire (a dead tally running every row to full budget must not pass)
    early_stop_saves = (
        sweep[0]["steps_taken"] < base.n_steps
        and sweep[0]["steps_taken"] <= sweep[-1]["steps_taken"]
    )
    return {
        "graph": {"n_pins": g.n_pins, "n_boards": g.n_boards},
        "sweep": sweep,
        "both_backends_agree": agree,
        "early_stop_saves_steps": bool(early_stop_saves),
    }


def run(seed: int = 0) -> Dict:
    out: Dict = {
        "host_backend": jax.default_backend(),
        "pallas_interpret": jax.default_backend() == "cpu",
        "counting": _counting_microbench(seed),
        "walk": _walk_sweep(seed),
    }
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))
