"""Fast serving smoke: tiny graph, both walk backends, perf trajectory file.

Runs the batched query path (core/service.serve_batch) on a tiny synthetic
graph with the "xla" and "pallas" walk engines, checks they return identical
recommendations, and writes ``BENCH_serving.json`` at the repo root so future
PRs have a perf trajectory to regress against.

Numbers recorded on a CPU host run the Pallas kernels in *interpret mode* —
they measure correctness plumbing, not kernel speed (`host_backend` in the
output says which).  On a TPU host the same file records the real fused-kernel
speedup.

The ``earlystop`` section runs the same batch with Algorithm 3's early
stopping ACTIVE, exercising the fused in-VMEM ``n_high`` tally on the
serving path; ``earlystop_backends_agree`` asserts both engines return
bit-identical ids, steps_taken, and n_high — that (plus
``both_backends_agree``) is the regression signal on CPU hosts, not the
interpret-mode timing ratio.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BENCH_SERVING_PATH, MERGED_SECTIONS, timed
from repro.core import service, walk as walk_lib
from repro.graphs.synthetic import SyntheticGraphConfig, generate

OUT_PATH = BENCH_SERVING_PATH


def run(seed: int = 0) -> Dict:
    sg = generate(SyntheticGraphConfig(
        n_pins=2_000, n_boards=200, n_topics=8, n_langs=2, seed=seed
    ))
    g = sg.graph
    rng = np.random.default_rng(seed)
    degs = np.asarray(g.p2b.degrees()).astype(np.float64)
    qs = rng.choice(g.n_pins, size=8, replace=False, p=degs / degs.sum())

    batch = 4
    n_slots = 2
    pins = np.full((batch, n_slots), -1, np.int32)
    weights = np.zeros((batch, n_slots), np.float32)
    for i in range(batch):
        pins[i, 0] = qs[2 * i]
        pins[i, 1] = qs[2 * i + 1]
        weights[i] = [1.0, 0.6]
    pins_j = jnp.asarray(pins)
    weights_j = jnp.asarray(weights)
    feats = jnp.zeros((batch,), jnp.int32)
    key = jax.random.key(seed)

    base = walk_lib.WalkConfig(
        n_steps=2_000, n_walkers=128, chunk_steps=8, top_k=20,
        n_p=10**9, n_v=10**9,
    )

    out: Dict = {
        "host_backend": jax.default_backend(),
        "pallas_interpret": jax.default_backend() == "cpu",
        "graph": {"n_pins": g.n_pins, "n_boards": g.n_boards,
                  "n_edges": g.n_edges},
        "config": {"n_steps": base.n_steps, "n_walkers": base.n_walkers,
                   "chunk_steps": base.chunk_steps, "batch": batch},
        "backends": {},
    }
    ids_by_backend = {}
    for backend in ("xla", "pallas"):
        fn = jax.jit(
            lambda k, b=backend: service.serve_batch(
                g, pins_j, weights_j, feats, k, base, backend=b
            )
        )
        t = timed(fn, key, warmup=1, iters=3)
        scores, ids = fn(key)
        ids_by_backend[backend] = np.asarray(ids)
        out["backends"][backend] = {
            "batch_ms": round(t["mean_ms"], 2),
            "per_query_ms": round(t["mean_ms"] / batch, 2),
        }

    out["both_backends_agree"] = bool(
        np.array_equal(ids_by_backend["xla"], ids_by_backend["pallas"])
    )
    x_ms = out["backends"]["xla"]["batch_ms"]
    p_ms = out["backends"]["pallas"]["batch_ms"]
    out["pallas_speedup_x"] = round(x_ms / max(p_ms, 1e-9), 3)

    # early stopping active: the fused in-VMEM n_high tally on the hot path
    es_cfg = dataclasses.replace(base, n_p=60, n_v=3)
    es = {"config": {"n_p": es_cfg.n_p, "n_v": es_cfg.n_v}, "backends": {}}
    es_out = {}
    for backend in ("xla", "pallas"):
        fn = jax.jit(
            lambda k, b=backend: service.serve_batch(
                g, pins_j, weights_j, feats, k, es_cfg, backend=b,
                with_stats=True,
            )
        )
        t = timed(fn, key, warmup=1, iters=3)
        _, ids, steps, n_high = fn(key)
        es_out[backend] = (np.asarray(ids), np.asarray(steps),
                           np.asarray(n_high))
        es["backends"][backend] = {
            "batch_ms": round(t["mean_ms"], 2),
            "mean_steps": float(np.asarray(steps).mean()),
            "mean_n_high": float(np.asarray(n_high).mean()),
        }
    es["earlystop_backends_agree"] = bool(
        all(np.array_equal(a, b)
            for a, b in zip(es_out["xla"], es_out["pallas"]))
    )
    # the thresholds actually stop the walk before the full budget
    es["stops_early"] = bool(
        (es_out["xla"][1].sum(axis=-1) < base.n_steps).all()
    )
    out["earlystop"] = es
    out["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%S")
    # other suites merge their sections into this file; a smoke-only rerun
    # must not silently erase them (check_verdicts asserts they exist) —
    # benchmarks/common.MERGED_SECTIONS is the registry
    if os.path.exists(OUT_PATH):
        try:
            with open(OUT_PATH) as f:
                prev = json.load(f)
            for section in MERGED_SECTIONS:
                if section in prev:
                    out[section] = prev[section]
        except Exception:
            pass
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=2)
    out["wrote"] = OUT_PATH
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))
