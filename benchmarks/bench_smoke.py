"""Fast serving smoke: tiny graph, both walk backends, perf trajectory file.

Runs the batched query path (core/service.serve_batch) on a tiny synthetic
graph with the "xla" and "pallas" walk engines, checks they return identical
recommendations, and writes ``BENCH_serving.json`` at the repo root so future
PRs have a perf trajectory to regress against.

Numbers recorded on a CPU host run the Pallas kernels in *interpret mode* —
they measure correctness plumbing, not kernel speed (`host_backend` in the
output says which).  On a TPU host the same file records the real fused-kernel
speedup.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.core import service, walk as walk_lib
from repro.graphs.synthetic import SyntheticGraphConfig, generate

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                        "BENCH_serving.json")


def run(seed: int = 0) -> Dict:
    sg = generate(SyntheticGraphConfig(
        n_pins=2_000, n_boards=200, n_topics=8, n_langs=2, seed=seed
    ))
    g = sg.graph
    rng = np.random.default_rng(seed)
    degs = np.asarray(g.p2b.degrees()).astype(np.float64)
    qs = rng.choice(g.n_pins, size=8, replace=False, p=degs / degs.sum())

    batch = 4
    n_slots = 2
    pins = np.full((batch, n_slots), -1, np.int32)
    weights = np.zeros((batch, n_slots), np.float32)
    for i in range(batch):
        pins[i, 0] = qs[2 * i]
        pins[i, 1] = qs[2 * i + 1]
        weights[i] = [1.0, 0.6]
    pins_j = jnp.asarray(pins)
    weights_j = jnp.asarray(weights)
    feats = jnp.zeros((batch,), jnp.int32)
    key = jax.random.key(seed)

    base = walk_lib.WalkConfig(
        n_steps=2_000, n_walkers=128, chunk_steps=8, top_k=20,
        n_p=10**9, n_v=10**9,
    )

    out: Dict = {
        "host_backend": jax.default_backend(),
        "pallas_interpret": jax.default_backend() == "cpu",
        "graph": {"n_pins": g.n_pins, "n_boards": g.n_boards,
                  "n_edges": g.n_edges},
        "config": {"n_steps": base.n_steps, "n_walkers": base.n_walkers,
                   "chunk_steps": base.chunk_steps, "batch": batch},
        "backends": {},
    }
    ids_by_backend = {}
    for backend in ("xla", "pallas"):
        fn = jax.jit(
            lambda k, b=backend: service.serve_batch(
                g, pins_j, weights_j, feats, k, base, backend=b
            )
        )
        t = timed(fn, key, warmup=1, iters=3)
        scores, ids = fn(key)
        ids_by_backend[backend] = np.asarray(ids)
        out["backends"][backend] = {
            "batch_ms": round(t["mean_ms"], 2),
            "per_query_ms": round(t["mean_ms"] / batch, 2),
        }

    out["both_backends_agree"] = bool(
        np.array_equal(ids_by_backend["xla"], ids_by_backend["pallas"])
    )
    x_ms = out["backends"]["xla"]["batch_ms"]
    p_ms = out["backends"]["pallas"]["batch_ms"]
    out["pallas_speedup_x"] = round(x_ms / max(p_ms, 1e-9), 3)
    out["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%S")
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=2)
    out["wrote"] = OUT_PATH
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))
