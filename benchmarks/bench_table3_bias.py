"""Table 3: personalized (biased) walk — target-language content fraction.

BasicRandomWalk vs PixieRandomWalk with the user's language as the bias
feature, querying from (a) a dominant-language pin and (b) a target-language
pin; report % of top-100 recommendations in the target language.  The paper
shows e.g. En->Japanese 16.35% -> 80.33% and Japanese->Japanese 52.95% ->
100%; the claim under test is the large lift in both columns.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_graph
from repro.core import walk as walk_lib


def _lang_frac(sg, ids, vals, lang):
    ids, vals = np.asarray(ids), np.asarray(vals)
    ids = ids[vals > 0][:100]
    if ids.size == 0:
        return 0.0
    return float(np.mean(sg.pin_lang[ids] == lang))


def run(n_queries: int = 15, seed: int = 0) -> Dict:
    sg = bench_graph()
    g = sg.graph
    rng = np.random.default_rng(seed)
    degs = np.asarray(g.p2b.degrees())

    base_cfg = walk_lib.WalkConfig(
        n_steps=20_000, n_walkers=256, top_k=100, n_p=10**9, n_v=10**9,
    )
    basic = walk_lib.WalkConfig(**{**base_cfg.__dict__, "bias_beta": 0.0})
    pixie = walk_lib.WalkConfig(**{**base_cfg.__dict__, "bias_beta": 0.95})

    out: Dict = {}
    for target in (1, 2, 3):
        rows = {"basic_from_dominant": [], "pixie_from_dominant": [],
                "basic_from_target": [], "pixie_from_target": []}
        dom_pins = np.where((sg.pin_lang == 0) & (degs >= 3))[0]
        tgt_pins = np.where((sg.pin_lang == target) & (degs >= 3))[0]
        for i in range(n_queries):
            for src_name, pool in (("dominant", dom_pins), ("target", tgt_pins)):
                if pool.size == 0:
                    continue
                q = int(rng.choice(pool))
                qp = jnp.asarray([q], jnp.int32)
                qw = jnp.ones((1,), jnp.float32)
                key = jax.random.key(seed * 1000 + target * 100 + i)
                for cfg_name, cfg in (("basic", basic), ("pixie", pixie)):
                    vals, ids = walk_lib.recommend(
                        g, qp, qw, jnp.asarray(target, jnp.int32), key, cfg
                    )
                    rows[f"{cfg_name}_from_{src_name}"].append(
                        _lang_frac(sg, ids, vals, target)
                    )
        out[f"lang_{target}"] = {
            k: float(np.mean(v)) if v else None for k, v in rows.items()
        }
    # reproduction check: pixie boosts target-language fraction in both cols
    lifts = []
    for t in out.values():
        if t["pixie_from_dominant"] is not None:
            lifts.append(t["pixie_from_dominant"] >= t["basic_from_dominant"])
            lifts.append(t["pixie_from_target"] >= t["basic_from_target"])
    out["bias_lift_reproduced"] = bool(all(lifts))
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))
