"""Single source of truth for the CI agreement verdicts.

CPU runners interpret the Pallas kernels, so timings are meaningless
there — the regression signal is the set of bit-identical xla/pallas
agreement verdicts recorded by the smoke suites.  This module owns the
list of (file, path) verdicts CI asserts, so adding a suite means adding
a line HERE, not editing a YAML heredoc.

Run locally after the smokes:

    PYTHONPATH=src python -m benchmarks.run \
        --only smoke earlystop_fused widepack dma_gather batchfuse \
        sharded traffic two_stage multi_interest chaos
    PYTHONPATH=src python -m benchmarks.check_verdicts

Exit code 0 iff every verdict is present and truthy.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Iterable, Tuple

# (file, key path) pairs; every leaf must exist and be truthy.
VERDICTS: Tuple[Tuple[str, Tuple[str, ...]], ...] = (
    # bench_smoke: serving path, both walk engines, early stopping active
    ("BENCH_serving.json", ("both_backends_agree",)),
    ("BENCH_serving.json", ("earlystop", "earlystop_backends_agree")),
    ("BENCH_serving.json", ("earlystop", "stops_early")),
    # bench_widepack (merged into the serving trajectory file): wide
    # (slot, pin) lanes past 2**31 packed ids + incremental event checks
    ("BENCH_serving.json", ("widepack", "widepack_backends_agree")),
    ("BENCH_serving.json", ("widepack", "incremental_matches_full")),
    # bench_dma_gather (merged): async-DMA CSR prefetch == scalar == xla
    ("BENCH_serving.json", ("dma", "dma_backends_agree")),
    # bench_batchfuse (merged): batch-native engine == vmapped per-query
    # path bit-identically (ids, scores, steps_taken, n_high) AND one
    # pallas program per chunk independent of batch size
    ("BENCH_serving.json", ("batchfuse", "batch_engine_agrees")),
    # bench_sharded (merged): pod-sharded batched fused engine == xla
    # sharded twin == unsharded batched engine bit-identically (counts,
    # board counts, steps_taken, n_high) across n_shards x batch, zero
    # drops at parity slack, and starved-fabric drops are counted
    ("BENCH_serving.json", ("sharded", "sharded_engine_agrees")),
    # bench_traffic (merged): bucketed deadline-aware batch formation ==
    # single-bucket flush() oracle score-for-score on the same requests
    # and RNG streams, with the daily graph swap exercised under load
    ("BENCH_serving.json", ("traffic", "traffic_buckets_agree")),
    # bench_two_stage (merged): fused pallas two-stage path == XLA oracle
    # bit-identically (stage-1 candidate ids, ranker scores, final
    # ordering, walk telemetry) across batch {1,4,16} x gather
    # {scalar,dma} with mixed scenario heads, AND a constant pallas_call
    # count independent of batch size (jaxpr-pinned)
    ("BENCH_serving.json", ("two_stage", "two_stage_backends_agree")),
    # bench_multi_interest (merged): fused multi-interest serving (cluster
    # lanes with importance-proportional step budgets in ONE batched walk
    # + the bit-reproducible Eq. 3 cross-cluster merge) == the per-cluster
    # single-query oracle bit-identically across users {1,4,16} x
    # k {1,2,4} x backend {xla,pallas} x gather {scalar,dma}, k=1
    # collapsing exactly to the flat homefeed path, with a constant
    # pallas_call count as k grows (jaxpr-pinned: lanes, not launches)
    ("BENCH_serving.json", ("multi_interest", "multi_interest_agrees")),
    # bench_chaos (merged): degraded-mode serving — chaos-run shed budgets
    # replayed through an unloaded submit(budget=...) oracle bit-identically
    # across backend x gather, zero-fault chaos == plain open-loop run
    # bit-for-bit, and dead-shard serving kills-and-counts walkers, zeroes
    # the dead shard's counts, quantifies overlap@k, and revives bit-clean
    ("BENCH_serving.json", ("chaos", "degraded_serving_agrees")),
    # bench_earlystop_fused: fused in-VMEM tally == naive recount
    ("results/bench.json", ("earlystop_fused", "counting",
                            "fused_matches_naive")),
    ("results/bench.json", ("earlystop_fused", "walk",
                            "both_backends_agree")),
    # widepack suite verdicts as recorded by the driver
    ("results/bench.json", ("widepack", "widepack_backends_agree")),
    ("results/bench.json", ("widepack", "incremental_matches_full")),
    # dma_gather suite verdict as recorded by the driver
    ("results/bench.json", ("dma_gather", "dma_backends_agree")),
)


def _lookup(tree, path: Iterable[str]):
    for key in path:
        if not isinstance(tree, dict) or key not in tree:
            return None
        tree = tree[key]
    return tree


def check(root: str = ".") -> int:
    """Print every verdict; return the number of missing/false ones."""
    import os

    cache = {}
    n_bad = 0
    for fname, path in VERDICTS:
        fpath = os.path.join(root, fname)
        if fname not in cache:
            try:
                with open(fpath) as f:
                    cache[fname] = json.load(f)
            except Exception as e:
                cache[fname] = e
        tree = cache[fname]
        label = f"{fname}:{'.'.join(path)}"
        if isinstance(tree, Exception):
            print(f"MISSING {label} ({type(tree).__name__}: {tree})")
            n_bad += 1
            continue
        val = _lookup(tree, path)
        if val is None:
            print(f"MISSING {label}")
            n_bad += 1
        elif not val:
            print(f"FAIL    {label} = {val!r}")
            n_bad += 1
        else:
            print(f"ok      {label}")
    return n_bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--root", default=".",
                    help="repo root holding the result files")
    ap.add_argument("--list", action="store_true",
                    help="print the verdict list and exit")
    args = ap.parse_args(argv)
    if args.list:
        for fname, path in VERDICTS:
            print(f"{fname}:{'.'.join(path)}")
        return 0
    n_bad = check(args.root)
    total = len(VERDICTS)
    print(f"\nagreement verdicts: {total - n_bad}/{total} ok")
    return 1 if n_bad else 0


if __name__ == "__main__":
    sys.exit(main())
