"""Continuous-traffic serving: latency vs offered QPS under an open-loop
Poisson load generator, plus the bucketed-serving agreement verdict.

The paper's headline systems claim is 1,200 QPS at 60 ms p99 per machine
(§3.3).  This suite exercises the production serving shape built in
``serving/server.py`` + ``serving/traffic.py``:

  * ``traffic_buckets_agree`` — the CI verdict: multi-bucket
    deadline-aware serving (mixed query sizes routed to small/medium/large
    ``(batch, n_slots)`` buckets, dispatch on max-wait OR full) returns
    SCORE-FOR-SCORE identical recommendations to the single-bucket
    ``flush()`` oracle on the same requests and RNG streams (per-request
    ``fold_in`` keys make a query's walk independent of batch
    composition), WITH the daily graph swap (§3.3) fired mid-run under
    load — pre-swap requests must carry the old generation, post-swap the
    new, and the generation must move exactly once.

  * the latency-vs-offered-QPS curve: a seeded Poisson sweep over offered
    load, recording p50/p95/p99, achieved QPS, drop rate (open-loop load
    shedding past a backlog bound), and the queue-wait vs compute split.
    On CPU hosts compute is interpret-free xla but still host-bound —
    regress on the verdict, never on the CPU curve.

Results land in ``results/bench.json`` AND merge into
``BENCH_serving.json`` as the ``traffic`` section.
"""

from __future__ import annotations

import json
from typing import Dict

import numpy as np

from benchmarks.common import merge_serving_section
from repro.core import walk as walk_lib
from repro.graphs.synthetic import SyntheticGraphConfig, generate
from repro.serving.server import PixieServer
from repro.serving.traffic import (
    OpenLoopConfig, poisson_requests, run_open_loop,
)

BUCKETS = ((6, 2), (4, 4), (2, 8))   # small / medium / large (batch, slots)
ORACLE_BATCH = 4                      # single-bucket flush oracle shape
MAX_WAIT_MS = 4.0


def _graph(seed: int):
    return generate(SyntheticGraphConfig(
        n_pins=2_000, n_boards=200, n_topics=8, n_langs=2, seed=seed
    ))


def _cfg() -> walk_lib.WalkConfig:
    # xla backend: the traffic suite measures BATCH FORMATION, not the
    # step engines (their parity has its own verdicts); interpret-mode
    # pallas would just slow the sweep down on CPU CI hosts
    return walk_lib.WalkConfig(
        n_steps=1_500, n_walkers=64, chunk_steps=8, top_k=20,
        n_p=60, n_v=3,
    )


def _hot_pins(g, n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    degs = np.asarray(g.p2b.degrees()).astype(np.float64)
    return rng.choice(g.n_pins, size=n, replace=False,
                      p=degs / degs.sum()).astype(np.int32)


def _agreement(seed: int) -> Dict:
    """Bucketed deadline-aware serving vs the single-bucket flush oracle,
    same requests, same RNG streams, graph swap fired under load."""
    sg = _graph(seed)
    g = sg.graph
    cfg = _cfg()
    candidates = _hot_pins(g, 64, seed)
    workload = poisson_requests(candidates, OpenLoopConfig(
        offered_qps=200.0, n_requests=24, seed=seed, max_pins=8,
    ))
    swap_at = len(workload) // 2

    bucketed = PixieServer(
        g, cfg, seed=seed, buckets=BUCKETS, max_wait_ms=MAX_WAIT_MS,
    )
    report = run_open_loop(
        bucketed, workload, max_backlog_s=None,
        swap_at=swap_at, swap_graph=g,
    )

    # oracle: ONE bucket wide enough for every query, synchronous flush
    oracle = PixieServer(
        g, cfg, batch_size=ORACLE_BATCH, n_slots=8, seed=seed,
    )
    for req in workload:
        oracle.submit(list(req.pins), list(req.weights), req.user_feat,
                      req_id=req.req_id)
    oracle_out = {r.req_id: r for r in oracle.flush()}

    agree = len(report.results) == len(workload) == len(oracle_out)
    for req in workload:
        b = report.results.get(req.req_id)
        o = oracle_out.get(req.req_id)
        if b is None or o is None:
            agree = False
            break
        agree &= bool(np.array_equal(b.scores, o.scores))
        agree &= bool(np.array_equal(b.ids, o.ids))
        if not agree:
            break

    gens = report.generations
    pre = [gens[r.req_id] for r in workload[:swap_at] if r.req_id in gens]
    post = [gens[r.req_id] for r in workload[swap_at:] if r.req_id in gens]
    # pre-swap arrivals may still DISPATCH post-swap (deadline formation),
    # so pre-swap generations may be 0 or 1; post-swap submissions must
    # all be generation 1, and at least one request must have served on
    # the old graph for the swap to count as "under load"
    swap_ok = (
        bucketed.stats.graph_generation == 1
        and all(v == 1 for v in post)
        and any(v == 0 for v in pre)
        and all(v in (0, 1) for v in pre)
    )
    return {
        "n_requests": len(workload),
        "swap_at": swap_at,
        "n_served_bucketed": len(report.results),
        "scores_ids_identical": bool(agree),
        "swap_under_load_ok": bool(swap_ok),
        "pre_swap_generations": sorted(set(pre)),
        "post_swap_generations": sorted(set(post)),
        "drop_rate": report.drop_rate,
    }


def _qps_sweep(seed: int, qps_points, n_requests: int) -> Dict:
    """Latency vs offered QPS: the Fig. 1-style serving trajectory."""
    sg = _graph(seed)
    g = sg.graph
    cfg = _cfg()
    candidates = _hot_pins(g, 64, seed)
    rows = []
    for qps in qps_points:
        server = PixieServer(
            g, cfg, seed=seed, buckets=BUCKETS, max_wait_ms=MAX_WAIT_MS,
        )
        # warm every bucket shape before offering load, so the sweep
        # measures serving, not compilation
        for _, slots in server._buckets:
            server.submit([int(candidates[0])] * slots, [1.0] * slots,
                          now=-10.0)
            server.pump(now=0.0)
        server.harvest()
        server.stats.latencies_ms.clear()
        server.stats.wait_ms.clear()
        server.stats.compute_ms.clear()
        server.stats.queries = 0

        workload = poisson_requests(candidates, OpenLoopConfig(
            offered_qps=float(qps), n_requests=n_requests, seed=seed,
            max_pins=8,
        ))
        report = run_open_loop(server, workload, max_backlog_s=2.0)
        rows.append(report.summary())
    return {"rows": rows}


def run(seed: int = 0, qps_points=(25.0, 100.0, 400.0),
        n_requests: int = 24) -> Dict:
    import jax

    agreement = _agreement(seed)
    sweep = _qps_sweep(seed, qps_points, n_requests)
    out: Dict = {
        "host_backend": jax.default_backend(),
        "buckets": [list(b) for b in BUCKETS],
        "max_wait_ms": MAX_WAIT_MS,
        "agreement": agreement,
        "qps_sweep": sweep,
    }
    out["traffic_buckets_agree"] = bool(
        agreement["scores_ids_identical"] and agreement["swap_under_load_ok"]
    )
    out["wrote"] = merge_serving_section("traffic", {
        "traffic_buckets_agree": out["traffic_buckets_agree"],
        "buckets": out["buckets"],
        "max_wait_ms": MAX_WAIT_MS,
        "swap_under_load_ok": agreement["swap_under_load_ok"],
        "qps_sweep": sweep["rows"],
    })
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))
