"""Table 1: hit-rate of ranking the related pin — Pixie vs content baselines.

Paper setup: user looking at query pin q saved related pin x; rank all pins,
report the fraction of queries where x lands in the top-K.  Synthetic
analogue: x is a co-board pin of q (the same "saved together" relation the
Pinterest graph encodes); the content baselines rank by noisy topic-vector
embeddings (textual-cosine / visual-hamming / rank-sum combined), exactly
the baseline family the paper compares against.

Expected reproduction: Pixie >> combined-content > single-modality content
(paper: 52.2% vs 10.5% vs ~4.6% at K=1000 — magnitudes differ on a
synthetic graph; the ORDERING is the claim under test).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_graph, sample_query_pins
from repro.core import baselines, walk as walk_lib

KS = (10, 100, 1000)


def run(n_queries: int = 40, seed: int = 0) -> Dict:
    sg = bench_graph()
    g = sg.graph
    rng = np.random.default_rng(seed)
    queries = sample_query_pins(sg, n_queries, seed)

    # ground truth: a co-board neighbour of q (the "saved next" pin)
    p2b_off = np.asarray(g.p2b.offsets)
    p2b_tgt = np.asarray(g.p2b.targets)
    b2p_off = np.asarray(g.b2p.offsets)
    b2p_tgt = np.asarray(g.b2p.targets)

    def co_board_pin(q):
        lo, hi = p2b_off[q], p2b_off[q + 1]
        if hi == lo:
            return None
        b = p2b_tgt[rng.integers(lo, hi)] - g.n_pins
        blo, bhi = b2p_off[b], b2p_off[b + 1]
        cands = b2p_tgt[blo:bhi]
        cands = cands[cands != q]
        if cands.size == 0:
            return None
        return int(rng.choice(cands))

    text, vis = baselines.make_content_embeddings(sg.pin_topics, seed=seed)
    text_j, vis_j = jnp.asarray(text), jnp.asarray(vis)

    cfg = walk_lib.WalkConfig(
        n_steps=30_000, n_walkers=512, top_k=1000, bias_beta=0.0,
        n_p=10**9, n_v=10**9,
    )

    hits = {m: {k: 0 for k in KS} for m in
            ("content_text", "content_visual", "content_combined", "pixie")}
    n_eval = 0
    for qi, q in enumerate(queries):
        x = co_board_pin(int(q))
        if x is None:
            continue
        n_eval += 1
        scores = {
            "content_text": np.asarray(
                baselines.cosine_rank_scores(text_j, int(q))
            ),
            "content_visual": np.asarray(
                baselines.hamming_rank_scores(vis_j, int(q))
            ),
            "content_combined": np.asarray(
                baselines.combined_rank_scores(text_j, vis_j, int(q))
            ),
        }
        for name, s in scores.items():
            s = s.copy()
            s[int(q)] = -np.inf
            rank = int(np.sum(s > s[x]))
            for k in KS:
                hits[name][k] += int(rank < k)

        qp = jnp.asarray([int(q)], jnp.int32)
        qw = jnp.ones((1,), jnp.float32)
        vals, ids = walk_lib.recommend(
            g, qp, qw, jnp.asarray(0, jnp.int32),
            jax.random.key(seed + qi), cfg,
        )
        ids = np.asarray(ids)
        vals = np.asarray(vals)
        pos = np.where((ids == x) & (vals > 0))[0]
        rank = int(pos[0]) if pos.size else 10**9
        for k in KS:
            hits["pixie"][k] += int(rank < k)

    table = {
        m: {f"top_{k}": hits[m][k] / max(n_eval, 1) for k in KS}
        for m in hits
    }
    ok = all(
        table["pixie"][f"top_{k}"] >= table["content_combined"][f"top_{k}"]
        for k in KS
    )
    return {"table": table, "n_queries": n_eval,
            "ordering_reproduced": bool(ok)}


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))
