"""Figure 5: memory usage and walk runtime vs pruning factor.

Claims under test: both graph bytes and walk wall-time decrease as the
graph is pruned harder (the paper's 6x memory cut at peak-F1 delta).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from benchmarks.common import bench_graph, sample_query_pins, timed
from repro.core import pruning, walk as walk_lib


def run(seed: int = 0) -> Dict:
    sg = bench_graph()
    qs = sample_query_pins(sg, 4, seed)
    out = {"sweep": []}
    for delta in (1.0, 0.9, 0.75, 0.6):
        cfg = pruning.PruneConfig(entropy_board_frac=0.10, delta=delta)
        pruned, stats = pruning.prune_graph(
            sg.graph, sg.pin_topics, None, cfg,
            board_lang=sg.board_lang, pin_lang=sg.pin_lang, n_langs=4,
        )
        wcfg = walk_lib.WalkConfig(
            n_steps=20_000, n_walkers=256, top_k=100, n_p=10**9, n_v=10**9
        )
        qp = jnp.asarray([int(qs[0])], jnp.int32)
        qw = jnp.ones((1,), jnp.float32)
        fn = jax.jit(
            lambda k: walk_lib.recommend(
                pruned, qp, qw, jnp.asarray(0, jnp.int32), k, wcfg
            )
        )
        t = timed(fn, jax.random.key(seed), warmup=1, iters=3)
        out["sweep"].append({
            "delta": delta,
            "graph_mbytes": round(pruned.nbytes() / 1e6, 3),
            "runtime_ms": round(t["mean_ms"], 1),
            "edges": stats["edges_after"],
        })
    rows = out["sweep"]
    out["memory_decreases"] = bool(
        all(rows[i]["graph_mbytes"] >= rows[i + 1]["graph_mbytes"]
            for i in range(len(rows) - 1))
    )
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))
